"""Batched simulation core + hot-key replication (ISSUE 4).

Covers: the deferred-touch sketch's exact equivalence to touch-immediately
conservative update (property tests over random interleavings and aging
boundaries), ``top_k``/``estimate_many`` views, the tuple-backed EventQueue
order parity, replication invariants (capacity never exceeded, owner copy
untouched by demotion, no flapping inside the hysteresis band), the
admission-bypass spill feed, cost-aware (slot-value) admission semantics,
the adaptive prefetch depth guard's acceptance cells, and the digest locks
proving every PR-3 table is bit-identical with the new features off.
"""
import hashlib
import random

from benchmarks import tables
from repro.agent.backends import Profile, SimLLM
from repro.agent.concurrency import run_episode
from repro.agent.geollm.simclock import EventQueue
from repro.core.admission import FrequencySketch, TinyLFU, TinyLFUCost
from repro.core.cache import CacheEntry
from repro.core.distributed_cache import PodLocalCacheRouter
from repro.core.replication import (
    HotKeyReplicator,
    LLMReplication,
    ThresholdReplication,
    make_replication,
)


def _digest(rows) -> str:
    return hashlib.sha256(repr(rows).encode()).hexdigest()[:16]


def _entries(keys, sizes=None):
    return {k: CacheEntry(key=k, value=None,
                          size_bytes=(sizes or {}).get(k, 0),
                          created_at=0.0, last_access=float(i),
                          access_count=1, insert_order=i)
            for i, k in enumerate(keys)}


# ---------------------------------------------------------------------------
# Deferred-touch sketch: exact equivalence to touch-immediately
# ---------------------------------------------------------------------------

def test_touch_many_flush_matches_per_key_touch_exactly():
    """Property: a batched sketch (touches buffered, flushed once at the
    end) reports exactly the estimates of a sketch whose buffer is flushed
    after EVERY touch — over a random interleaving where collisions are
    plentiful (tiny width)."""
    rng = random.Random(42)
    keys = [f"k{i}-2020" for i in range(25)]
    stream = [rng.choice(keys) for _ in range(600)]
    eager = FrequencySketch(width=32, depth=4, age_period_s=0)
    lazy = FrequencySketch(width=32, depth=4, age_period_s=0)
    for k in stream:
        eager.touch(k)
        eager.flush()                  # touch-immediately semantics
    lazy.touch_many(stream)            # one deferred batch
    for k in keys:
        assert lazy.estimate(k) == eager.estimate(k), k
    assert (lazy.table == eager.table).all()


def test_deferred_touches_flush_in_arrival_order_at_reads():
    """Estimates read mid-stream see every prior touch (the flush boundary
    is any estimate call), so admission decisions cannot observe a stale
    sketch."""
    s = FrequencySketch(width=64, depth=4)
    for _ in range(5):
        s.touch("a-2020")
    assert s.estimate("a-2020") == 5   # buffer flushed by the read
    s.touch("a-2020")
    s.touch("b-2020")
    assert s.estimate_many(("a-2020", "b-2020")) == [6, 1]


def test_batched_aging_matches_eager_aging():
    """Aging boundaries interleave correctly with buffered touches: each
    touch carries its sim time, and crossing a boundary flushes what came
    before the halving."""
    eager = FrequencySketch(width=64, depth=4, age_period_s=10.0)
    lazy = FrequencySketch(width=64, depth=4, age_period_s=10.0)
    plan = [("a-2020", 1.0)] * 6 + [("b-2020", 4.0)] * 3 + \
           [("a-2020", 11.0)] * 2 + [("b-2020", 25.0)]
    for k, t in plan:
        eager.touch(k, now=t)
        eager.flush()
        lazy.touch(k, now=t)
    assert lazy.ages == eager.ages == 2
    for k in ("a-2020", "b-2020"):
        assert lazy.estimate(k) == eager.estimate(k)


def test_top_k_matches_bruteforce_and_is_deterministic():
    s = FrequencySketch(width=256, depth=4)
    rng = random.Random(3)
    keys = [f"k{i}-2021" for i in range(20)]
    for k in keys:
        s.touch_many([k] * rng.randint(0, 9))
    brute = sorted(((k, s.estimate(k)) for k in keys),
                   key=lambda kv: (-kv[1], kv[0]))
    assert s.top_k(5) == brute[:5]
    assert s.top_k(100) == brute        # k larger than population


def test_sketch_flush_counter_and_buffer_cap():
    from repro.core.admission import FLUSH_BUFFER_MAX
    s = FrequencySketch(width=64, depth=2, age_period_s=0)
    s.touch_many(["x-2020"] * (FLUSH_BUFFER_MAX + 10))
    assert s.flushes >= 1              # cap forced a flush mid-stream
    assert s.estimate("x-2020") == FLUSH_BUFFER_MAX + 10


# ---------------------------------------------------------------------------
# Tuple-backed EventQueue
# ---------------------------------------------------------------------------

def test_event_queue_fast_paths_agree_with_pop():
    def fill(q):
        q.push(2.0, 1, 3, "a")
        q.push(1.0, 0, 9, "b")
        q.push(1.0, 1, 0, "c")
        q.push(2.0, 0, 0, "d")
    q1, q2, q3 = EventQueue(), EventQueue(), EventQueue()
    fill(q1), fill(q2), fill(q3)
    order = [q1.pop().payload for _ in range(len(q1))]
    assert [q2.pop_payload() for _ in range(len(q2))] == order
    timed = [q3.pop_timed() for _ in range(len(q3))]
    assert [p for _, p in timed] == order
    assert [t for t, _ in timed] == [1.0, 1.0, 2.0, 2.0]


# ---------------------------------------------------------------------------
# Replication: router invariants
# ---------------------------------------------------------------------------

def _router_with_sketch(n_pods=3, capacity=2):
    sketch = FrequencySketch(width=256)
    r = PodLocalCacheRouter([f"p{i}" for i in range(n_pods)],
                            capacity_per_pod=capacity, sketch=sketch)
    return r, sketch


def test_replicate_charges_capacity_and_never_exceeds_it():
    r, sketch = _router_with_sketch(n_pods=3, capacity=2)
    # fill every pod to capacity with its own keys
    filled = []
    for key in (f"fill{i}-2020" for i in range(24)):
        pod = r.owner(key)
        if len(r.pods[pod]) < 2:
            r.install(pod, key, "V", 1)
            filled.append(key)
        if all(len(c) >= 2 for c in r.pods.values()):
            break
    sketch.touch_many(["hot-2020"] * 10)
    copies = r.replicate("hot-2020", "HOT", 1)
    assert copies >= 1
    for pod, cache in r.pods.items():
        assert len(cache) <= cache.capacity
    # the copy is findable and is NOT on the owner
    where = r.locate("hot-2020")
    assert where is not None and where != r.owner("hot-2020")


def test_replicate_skips_pods_with_hotter_residents():
    r, sketch = _router_with_sketch(n_pods=2, capacity=1)
    owner = r.owner("cand-2020")
    other = next(p for p in r.pods if p != owner)
    resident = next(k for k in (f"x{i}-2020" for i in range(50))
                    if r.owner(k) == other)
    r.install(other, resident, "R", 1)
    sketch.touch_many([resident] * 9 + ["cand-2020"] * 3)
    assert r.replicate("cand-2020", "C", 1) == 0      # resident hotter
    sketch.touch_many(["cand-2020"] * 20)
    assert r.replicate("cand-2020", "C", 1) == 1      # now decisively hotter


def test_drop_replica_leaves_owner_copy():
    r, sketch = _router_with_sketch(n_pods=2, capacity=2)
    key = "k-2020"
    owner = r.owner(key)
    r.install(owner, key, "V", 1)
    sketch.touch_many([key] * 8)
    r.replicate(key, "V", 1)
    assert len(r.replicas.get(key, [])) == 1
    dropped = r.drop_replica(key)
    assert dropped == 1
    assert key in r.pods[owner]              # owner copy untouched
    assert r.locate(key) == owner
    assert r.stats.replica_drops == 1


def test_locate_prefers_owner_and_verifies_membership():
    r, sketch = _router_with_sketch(n_pods=2, capacity=2)
    key = "q-2020"
    assert r.locate(key) is None
    sketch.touch_many([key] * 8)
    r.replicate(key, "V", 1)
    rep_pod = r.locate(key)
    assert rep_pod is not None and rep_pod != r.owner(key)
    # stale advisory entry: evict the replica behind the router's back
    r.pods[rep_pod].drop(key)
    assert r.locate(key) is None


# ---------------------------------------------------------------------------
# Replication: hysteresis (no flapping) + usage veto + spill feed
# ---------------------------------------------------------------------------

def _replicator(r, sketch, **kw):
    kw.setdefault("policy", ThresholdReplication(promote_min=8,
                                                 demote_frac=0.5))
    kw.setdefault("epoch_s", 10.0)
    kw.setdefault("miss_min", 1)
    return HotKeyReplicator(r, sketch, lambda k: "VAL", **kw)


def test_no_flapping_inside_hysteresis_band():
    """A replicated key whose estimate sits inside [demote_min,
    promote_min) and whose replica is being USED holds its replicas across
    epochs — it is neither dropped nor re-promoted (no flap)."""
    r, sketch = _router_with_sketch(n_pods=2, capacity=2)
    rep = _replicator(r, sketch)
    key = "band-2020"
    sketch.touch_many([key] * 8)
    r.demand_counts[key] = 3
    rep.run_epoch(10.0)
    assert key in rep.replicated and rep.stats.promotes == 1
    sketch.age()                       # halve: estimate 8 -> 4 (in band)
    assert rep.policy.demote_min <= sketch.estimate(key) \
        < rep.policy.promote_min
    for epoch in range(2, 5):
        r.replica_reads[key] = 1       # the replica is earning its slot
        rep.run_epoch(epoch * 10.0)
        assert key in rep.replicated, "dropped inside the hysteresis band"
    assert rep.stats.promotes == 1     # never re-promoted either


def test_unused_replica_dropped_after_grace():
    r, sketch = _router_with_sketch(n_pods=2, capacity=2)
    rep = _replicator(r, sketch)
    key = "idle-2020"
    sketch.touch_many([key] * 10)
    r.demand_counts[key] = 2
    rep.run_epoch(10.0)
    assert key in rep.replicated
    rep.run_epoch(20.0)                # grace epoch: still held
    assert key in rep.replicated
    rep.run_epoch(30.0)                # no reads for a full epoch: veto
    assert key not in rep.replicated
    assert rep.stats.demotes == 1


def test_demote_below_band_drops_replicas():
    r, sketch = _router_with_sketch(n_pods=2, capacity=2)
    rep = _replicator(r, sketch)
    key = "cool-2020"
    sketch.touch_many([key] * 8)
    r.demand_counts[key] = 2
    rep.run_epoch(10.0)
    assert key in rep.replicated
    sketch.age()
    sketch.age()                       # 8 -> 2 < demote_min 4
    r.replica_reads[key] = 5           # even a used replica goes below band
    rep.run_epoch(20.0)
    assert key not in rep.replicated
    assert r.locate(key) is None or r.locate(key) == r.owner(key)


def test_admission_bypass_feeds_spill_promotion():
    """router.install() offering bypassed keys to the replicator: the
    spill path promotes a hot-but-homeless key the moment admission
    rejects it at its full owner pod."""
    sketch = FrequencySketch(width=256)
    r = PodLocalCacheRouter(["p0", "p1"], capacity_per_pod=1,
                            admission=TinyLFU(), sketch=sketch)
    rep = _replicator(r, sketch)
    r.spill = rep.offer
    cand = "spill-2020"
    owner = r.owner(cand)
    resident = next(k for k in (f"r{i}-2020" for i in range(50))
                    if r.owner(k) == owner)
    r.install(owner, resident, "R", 1)
    sketch.touch_many([resident] * 20)         # resident wins at the owner
    sketch.touch_many([cand] * 10)             # candidate hot, but colder
    r.demand_counts[cand] = 3
    assert not r.install(owner, cand, "C", 1)  # bypassed at the owner ...
    assert cand in rep.replicated              # ... and spilled
    assert r.locate(cand) is not None


def test_llm_replication_graded_and_deterministic():
    llm = SimLLM(Profile("gpt-4-turbo", "cot", True), seed=5)
    pol = make_replication(impl="llm", llm=llm, promote_min=8)
    assert isinstance(pol, LLMReplication)
    sketch = FrequencySketch(width=256)
    sketch.touch_many(["h-2020"] * 12)
    decisions = [pol.decide("h-2020", sketch.estimate("h-2020"), False)
                 for _ in range(30)]
    assert pol.llm_total == 30
    assert decisions.count("replicate") >= 25   # eps-rate slips only
    assert 0.8 <= pol.agreement <= 1.0


# ---------------------------------------------------------------------------
# Cost-aware (slot-value) admission
# ---------------------------------------------------------------------------

def test_cost_admission_prefers_expensive_equal_frequency():
    """With slot-bounded capacity, equal frequencies resolve by miss
    penalty: a larger candidate may evict a smaller equally-hot victim,
    and a smaller candidate never evicts a larger equally-hot one."""
    s = FrequencySketch(width=256)
    s.touch_many(["big-2020"] * 4 + ["small-2020"] * 4)
    ents = _entries(["small-2020"], sizes={"small-2020": 10_000_000})
    p = TinyLFUCost()
    assert p.admit("big-2020", "small-2020", s, ents,
                   size_bytes=200_000_000)
    ents_big = _entries(["big-2020"], sizes={"big-2020": 200_000_000})
    assert not p.admit("small-2020", "big-2020", s, ents_big,
                       size_bytes=10_000_000)


def test_cost_admission_degrades_to_tinylfu_without_sizes():
    s = FrequencySketch(width=256)
    s.touch_many(["hot-2020"] * 5 + ["cold-2020"])
    p = TinyLFUCost()
    ents = _entries(["cold-2020"])
    assert p.admit("hot-2020", "cold-2020", s, ents, size_bytes=None)
    assert not p.admit("cold-2020", "hot-2020", s,
                       _entries(["hot-2020"]), size_bytes=None)


def test_cost_admission_engine_deterministic_on_wide_band():
    a = run_episode(4, 6, n_pods=2, reuse_rate=0.3, seed=1,
                    admission="tinylfu-cost",
                    rows_range=(2_000, 40_000)).metrics.row()
    b = run_episode(4, 6, n_pods=2, reuse_rate=0.3, seed=1,
                    admission="tinylfu-cost",
                    rows_range=(2_000, 40_000)).metrics.row()
    assert a == b


# ---------------------------------------------------------------------------
# Engine integration: replication + adaptive prefetch acceptance
# ---------------------------------------------------------------------------

ZIPFG = {"scenario": "zipf",
         "scenario_kw": {"zipf_a": 1.1, "zipf_global": True}}
REPL_KW = {"epoch_s": 20.0, "max_replicated": 10, "promote_min": 4,
           "miss_min": 2, "gain_ratio": 2.0}


def test_replication_deterministic_and_shifts_time_never_answers():
    base = run_episode(6, 6, n_pods=2, reuse_rate=0.3, seed=2,
                       admission="tinylfu", **ZIPFG)
    rep1 = run_episode(6, 6, n_pods=2, reuse_rate=0.3, seed=2,
                       admission="tinylfu", replication=True,
                       replication_kw=REPL_KW, **ZIPFG)
    rep2 = run_episode(6, 6, n_pods=2, reuse_rate=0.3, seed=2,
                       admission="tinylfu", replication=True,
                       replication_kw=REPL_KW, **ZIPFG)
    assert rep1.metrics.row() == rep2.metrics.row()
    for sb, sr in zip(base.sessions, rep1.sessions):
        assert [t.answers for t in sb.traces] == \
            [t.answers for t in sr.traces]


def test_replication_acceptance_16_4_zipf_global():
    """ISSUE-4 acceptance: at 16 sessions / 4 pods, TinyLFU+replication
    holds local hits strictly above the TinyLFU baseline with p95 no
    worse; replication alone beats install-everything decisively."""
    base = run_episode(16, 25, n_pods=4, reuse_rate=0.3, seed=0,
                       admission="tinylfu", **ZIPFG).metrics
    rep = run_episode(16, 25, n_pods=4, reuse_rate=0.3, seed=0,
                      admission="tinylfu", replication=True,
                      replication_kw=REPL_KW, **ZIPFG).metrics
    assert rep.local_hit_rate > base.local_hit_rate
    assert rep.p95_task_latency_s <= base.p95_task_latency_s
    assert rep.replica_hits > 0
    none = run_episode(16, 25, n_pods=4, reuse_rate=0.3, seed=0,
                       **ZIPFG).metrics
    ronly = run_episode(16, 25, n_pods=4, reuse_rate=0.3, seed=0,
                        replication=True, replication_kw=REPL_KW,
                        **ZIPFG).metrics
    assert ronly.local_hit_rate > none.local_hit_rate + 0.02
    assert ronly.p95_task_latency_s < none.p95_task_latency_s


def test_adaptive_prefetch_recovers_midrange_and_keeps_saturation():
    """ISSUE-4 satellite: the adaptive depth guard recovers the 8/8
    mid-range win (fixed guard 1.10 -> >= 1.18) without losing the 16/4
    saturation result (stays >= the fixed guard's speedup)."""
    lazy88 = run_episode(8, 25, n_pods=8, seed=0).metrics
    ad88 = run_episode(8, 25, n_pods=8, seed=0, prefetch=True,
                       prefetch_adaptive=True).metrics
    assert lazy88.p95_task_latency_s / ad88.p95_task_latency_s >= 1.18
    lazy164 = run_episode(16, 25, n_pods=4, seed=0).metrics
    # the engine defaults prefetch_adaptive=True since ISSUE 5: the fixed
    # guard must be pinned explicitly to stay the comparison baseline
    fx164 = run_episode(16, 25, n_pods=4, seed=0, prefetch=True,
                        prefetch_adaptive=False).metrics
    ad164 = run_episode(16, 25, n_pods=4, seed=0, prefetch=True,
                        prefetch_adaptive=True).metrics
    assert ad164.p95_task_latency_s <= fx164.p95_task_latency_s
    assert ad164.p95_task_latency_s <= lazy164.p95_task_latency_s


def test_replication_off_paths_reduce_to_owner_only():
    """With replication off, locate() is the owner-membership check and
    the replica-aware read path changes nothing (backstop for the digest
    locks below)."""
    res = run_episode(4, 6, n_pods=2, seed=3, admission="tinylfu")
    assert res.router.replicas == {}
    assert res.metrics.replica_hits == 0
    assert res.metrics.replication_epochs == 0


# ---------------------------------------------------------------------------
# Digest locks: every PR-3 table is bit-identical with ISSUE-4 features off
# ---------------------------------------------------------------------------

PR3_CONCURRENCY_DIGEST = "ef9a35183ca207bd"
PR3_PREFETCH_DIGEST = "4639ffe6b7da61d9"
PR3_ADMISSION_DIGEST = "a176d18b8439bf57"
PR3_BELADY_DIGEST = "0f372094aa0edaf3"


def test_concurrency_table_bit_identical_without_scale_cells():
    assert _digest(tables.table_concurrency(tasks_per_session=25,
                                            scale=())) \
        == PR3_CONCURRENCY_DIGEST


def test_prefetch_table_bit_identical_without_adaptive_rows():
    assert _digest(tables.table_prefetch(tasks_per_session=25,
                                         adaptive=False)) \
        == PR3_PREFETCH_DIGEST


def test_admission_table_bit_identical_without_extras():
    assert _digest(tables.table_admission(tasks_per_session=25,
                                          extras=False)) \
        == PR3_ADMISSION_DIGEST


def test_belady_table_bit_identical():
    assert _digest(tables.belady_bound(n=200)) == PR3_BELADY_DIGEST

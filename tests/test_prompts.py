import json

import pytest

from repro.core.prompts import (
    parse_json_tail,
    read_decision_prompt,
    update_decision_prompt,
)
from repro.agent.backends import Profile, SimLLM


def test_read_prompt_contains_contract():
    p = read_decision_prompt("show xview1 2022", ["xview1-2022"],
                             "{}", few_shot=True)
    assert "read_cache" in p and "load_db" in p
    assert "xview1-2022" in p
    assert "Example 1" in p
    p0 = read_decision_prompt("q", ["k-2020"], "{}", few_shot=False)
    assert "Example 1" not in p0


def test_update_prompt_contains_policy_text():
    p = update_decision_prompt("Least Recently Used (LRU): ...", ["a-2020"],
                               "{}", 5, few_shot=True)
    assert "at most 5 entries" in p
    assert "Least Recently Used" in p


def test_parse_json_tail_variants():
    assert parse_json_tail('Thought: blah\nAnswer: {"a": 1}') == {"a": 1}
    assert parse_json_tail('["x", "y"]') == ["x", "y"]
    with pytest.raises(ValueError):
        parse_json_tail("no json here")


def test_simllm_read_decision_parses_own_prompt():
    llm = SimLLM(Profile("gpt-4-turbo", "cot", True), seed=0)
    cache = json.dumps({"a-2020": {"last_access": 1.0}})
    p = read_decision_prompt("q", ["a-2020", "b-2021"], cache, few_shot=True)
    out = parse_json_tail(llm.complete(p))
    assert set(out) == {"a-2020", "b-2021"}
    assert out["a-2020"] in ("read_cache", "load_db")


def test_simllm_update_decision_applies_lru():
    llm = SimLLM(Profile("gpt-4-turbo", "cot", True), seed=0)
    cache = json.dumps({
        "a-2020": {"last_access": 1.0, "access_count": 1, "insert_order": 1},
        "b-2020": {"last_access": 9.0, "access_count": 1, "insert_order": 2},
    })
    p = update_decision_prompt(
        "Least Recently Used (LRU): evict the entry whose last access is "
        "the OLDEST.", ["c-2021"], cache, 2, few_shot=True)
    # eps small: across many draws the majority must evict "a"
    evicted_a = 0
    for seed in range(20):
        llm = SimLLM(Profile("gpt-4-turbo", "cot", True), seed=seed)
        state = parse_json_tail(llm.complete(p))
        if "a-2020" not in state and "c-2021" in state:
            evicted_a += 1
    assert evicted_a >= 17

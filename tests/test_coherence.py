"""Mutable data plane + cache coherence (ISSUE 8): property locks.

* **degeneracy contract** — ``mutations=None`` is the PR-7 engine
  verbatim, and an EMPTY :class:`MutationPlan` (every coherence hook
  live) replays it bit-identically — times, tokens, answers, every
  metric — including the locked PR-4 concurrency and PR-6 resilience
  table digests;
* **safety** — under write-invalidate / write-through no consumed value
  is EVER stale; under ttl / serve-stale no consumed value exceeds its
  declared staleness bound (the engine clamp applies to the GPT path
  too); every mutation eventually reaches every live copy (no lost
  invalidations — end-state version audit across pods and replicas);
* **freshness SLO** — the stale-read share is monotone non-decreasing
  in the mutation rate;
* **GPT-driven cache_update** — LLMCoherence agreement >= 90% with a
  fixed-seed golden transcript committed (tests/golden/
  cache_update.json);
* **satellite** — the diurnal/MMPP ``capacity_arrival`` cells obey the
  same flow-balance and Little's-law locks as the Poisson sweep.
"""
import hashlib
import json
import pathlib
import random

import pytest

from benchmarks import tables
from repro.agent.backends import Profile, SimLLM
from repro.agent.concurrency import run_episode
from repro.agent.geollm.workload import WorkloadSampler, mutation_hot_keys
from repro.core.coherence import (
    ARRIVAL,
    REFRESH,
    SERVE_STALE,
    UPDATE,
    LLMCoherence,
    MutationEvent,
    MutationPlan,
    ServeStaleCoherence,
    TTLCoherence,
    WriteInvalidate,
    WriteThrough,
    make_coherence,
)
from repro.core.faults import FaultPlan
from repro.core.traffic import DiurnalTraffic, MMPPTraffic

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

# the PR-4 / PR-6 references the degeneracy replays must keep matching
# (same values tests/test_locality.py and tests/test_traffic.py hold)
PR4_CONCURRENCY_DIGEST = "8ec8ff89cfb17741"
PR6_RESILIENCE_DIGEST_12 = "9ed9f62ca396989d"

HOT = mutation_hot_keys(4)


def _digest(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def _traces(res):
    return [(t.time_s, t.tokens, repr(t.answers))
            for s in res.sessions for t in s.traces]


_MEMO = {}


def _episode(policy="serve-stale", scenario="update_heavy", rate=0.2,
             seed=0, tasks=10, coherence_kw=None, **kw):
    """Memoised coherence episode (several tests read the same run)."""
    memo = repr((policy, scenario, rate, seed, tasks, coherence_kw,
                 sorted(kw.items(), key=repr)))
    if memo not in _MEMO:
        mutations = kw.pop("mutations",
                           MutationPlan.random_plan(HOT, rate, 150.0,
                                                    seed=5))
        _MEMO[memo] = run_episode(
            16, tasks, n_pods=4, reuse_rate=0.3, seed=seed,
            scenario=scenario, scenario_kw={"hot_k": 4, "hot_p": 0.85},
            mutations=mutations, coherence=policy,
            coherence_kw=coherence_kw, **kw)
    return _MEMO[memo]


# ---------------------------------------------------------------------------
# MutationPlan / MutationEvent construction + fail-fast validation
# ---------------------------------------------------------------------------

def test_plan_sorted_and_same_instant_order():
    """Construction order never matters; at one instant UPDATE lands
    before ARRIVAL, ties broken by key."""
    evs = [MutationEvent(5.0, "b", ARRIVAL), MutationEvent(5.0, "a", UPDATE),
           MutationEvent(5.0, "a", ARRIVAL), MutationEvent(1.0, "z", UPDATE)]
    plan = MutationPlan(evs)
    assert plan.events == MutationPlan(list(reversed(evs))).events
    assert [(e.at, e.key, e.kind) for e in plan] == [
        (1.0, "z", UPDATE), (5.0, "a", UPDATE), (5.0, "a", ARRIVAL),
        (5.0, "b", ARRIVAL)]
    assert not MutationPlan() and len(MutationPlan()) == 0
    assert len(plan) == 4 and bool(plan)


def test_plan_generators():
    single = MutationPlan.single("k", 10.0)
    assert [(e.at, e.key, e.kind) for e in single] == [(10.0, "k", UPDATE)]
    per = MutationPlan.periodic(["a", "b"], 30.0, start_s=30.0,
                                horizon_s=120.0, kind=ARRIVAL)
    assert [(e.at, e.key) for e in per] == [(30.0, "a"), (60.0, "b"),
                                            (90.0, "a")]
    assert all(e.kind == ARRIVAL for e in per)
    rnd = MutationPlan.random_plan(["a", "b"], 0.2, 100.0, seed=3,
                                   arrival_p=0.5)
    assert rnd.events == MutationPlan.random_plan(
        ["a", "b"], 0.2, 100.0, seed=3, arrival_p=0.5).events
    assert rnd.events != MutationPlan.random_plan(
        ["a", "b"], 0.2, 100.0, seed=4, arrival_p=0.5).events
    assert all(0.0 <= e.at < 100.0 and e.key in ("a", "b") for e in rnd)


def test_fail_fast_validation():
    """ISSUE-8 satellite: bad mutation/coherence parameters raise
    ValueError at construction, never corrupt an episode silently."""
    with pytest.raises(ValueError):
        MutationEvent(-1.0, "k", UPDATE)
    with pytest.raises(ValueError):
        MutationEvent(1.0, "k", "rewrite")
    with pytest.raises(ValueError):
        MutationEvent(1.0, "", UPDATE)
    with pytest.raises(ValueError):
        MutationPlan.periodic([], 5.0, horizon_s=50.0)
    with pytest.raises(ValueError):
        MutationPlan.periodic(["a"], 0.0, horizon_s=50.0)
    with pytest.raises(ValueError):
        MutationPlan.random_plan(["a"], -0.1, 50.0)
    with pytest.raises(ValueError):
        MutationPlan.random_plan(["a"], 0.1, 0.0)
    with pytest.raises(ValueError):
        MutationPlan.random_plan(["a"], 0.1, 50.0, arrival_p=1.5)
    with pytest.raises(ValueError):
        make_coherence("write-back")
    with pytest.raises(ValueError):
        make_coherence("ttl", ttl_s=0.0)
    with pytest.raises(ValueError):
        make_coherence("serve-stale", bound_s=-1.0)
    with pytest.raises(ValueError):
        make_coherence("serve-stale", impl="llm")        # llm required
    with pytest.raises(ValueError):
        make_coherence("write-invalidate", impl="llm",
                       llm=object())     # no read-time decision to wrap
    with pytest.raises(ValueError):
        mutation_hot_keys(0)
    with pytest.raises(ValueError):
        WorkloadSampler(scenario="update_light")


def test_engine_param_validation():
    with pytest.raises(ValueError):
        run_episode(2, 2, mutations=[MutationEvent(1.0, "k", UPDATE)])
    with pytest.raises(ValueError):                 # no mutable data plane
        run_episode(2, 2, coherence_impl="llm")
    with pytest.raises(ValueError):
        run_episode(2, 2, coherence_kw={"bound_s": 5.0})


# ---------------------------------------------------------------------------
# Coherence policies (unit)
# ---------------------------------------------------------------------------

def test_policy_flags_and_decisions():
    wi, wt = WriteInvalidate(), WriteThrough()
    assert wi.invalidate_on_write and not wi.refresh_on_write
    assert wt.refresh_on_write and not wt.invalidate_on_write
    assert wi.on_stale_read("k", 1.0, 1.0, 3) == REFRESH
    ttl = TTLCoherence(ttl_s=30.0)
    assert ttl.on_stale_read("k", 29.0, 29.0, 0) == SERVE_STALE
    assert ttl.on_stale_read("k", 31.0, 31.0, 0) == REFRESH
    assert ttl.expired(31.0) and not ttl.expired(29.0)
    st = ServeStaleCoherence(bound_s=20.0)
    assert st.bound_s == 20.0
    assert st.on_stale_read("k", 20.0, 20.0, 0) == SERVE_STALE
    assert st.on_stale_read("k", 20.1, 20.1, 0) == REFRESH
    assert not st.expired(100.0)


def test_make_coherence_factory():
    assert isinstance(make_coherence("write-invalidate"), WriteInvalidate)
    assert isinstance(make_coherence("write-through"), WriteThrough)
    assert make_coherence("ttl", ttl_s=12.0).ttl_s == 12.0
    assert make_coherence("serve-stale", bound_s=7.0).bound_s == 7.0
    llm = SimLLM(Profile("gpt-4-turbo", "cot", True), 1)
    pol = make_coherence("serve-stale", impl="llm", llm=llm)
    assert isinstance(pol, LLMCoherence) and pol.name == "llm-serve-stale"
    assert pol.bound_s == 20.0 and pol.agreement == 1.0


def test_llm_coherence_malformed_output_falls_back():
    class Broken:
        def complete(self, prompt):
            return "Thought: hmm.\nAnswer: not json at all"
    pol = LLMCoherence(ServeStaleCoherence(bound_s=20.0), Broken())
    assert pol.on_stale_read("k", 5.0, 5.0, 2) == SERVE_STALE
    assert pol.on_stale_read("k", 25.0, 25.0, 2) == REFRESH
    # malformed completions are counted as parse fallbacks, not graded:
    # the programmatic twin answered, so agreement must not move
    assert pol.parse_fallbacks == 2 and pol.llm_total == 0
    assert pol.agreement == 1.0
    assert pol.prompt_tokens > 0 and pol.completion_tokens > 0


# ---------------------------------------------------------------------------
# Degeneracy: no mutations == the PR-7 engine, bit-identical
# ---------------------------------------------------------------------------

def test_empty_plan_bit_identical_to_no_plane():
    base = run_episode(8, 6, n_pods=4, reuse_rate=0.3, seed=3,
                       prefetch=True)
    live = run_episode(8, 6, n_pods=4, reuse_rate=0.3, seed=3,
                       prefetch=True, mutations=MutationPlan())
    assert _traces(base) == _traces(live)
    b, l = base.metrics.row(), live.metrics.row()
    assert b == l
    assert live.coherence is not None and base.coherence is None
    assert live.coherence.stats.stale_reads == 0
    assert live.metrics.coherence_mutations == 0


def test_degeneracy_replays_pr4_concurrency_digest():
    """Digest lock: the full default concurrency table with every
    coherence hook live (empty plan) is bit-identical to the PR-4
    reference tests/test_locality.py locks on the plane-free engine."""
    rows = tables.table_concurrency(tasks_per_session=25,
                                    engine_kw={"mutations": MutationPlan()})
    assert _digest(rows) == PR4_CONCURRENCY_DIGEST


def test_degeneracy_replays_pr6_resilience_digest():
    """Digest lock at the fault-matrix level: coherence checkpoints
    compose with failover/retry/autoscale without moving a cell."""
    rows = tables.table_resilience(tasks_per_session=12,
                                   engine_kw={"mutations": MutationPlan()})
    assert _digest(rows) == PR6_RESILIENCE_DIGEST_12


# ---------------------------------------------------------------------------
# Safety: what every cell proved it served
# ---------------------------------------------------------------------------

def test_write_invalidate_never_serves_stale():
    for scenario in ("update_heavy", "mixed_rw", "flash_fresh"):
        res = _episode(policy="write-invalidate", scenario=scenario)
        m, coh = res.metrics, res.coherence
        assert m.coherence_mutations > 0
        assert m.coherence_stale_reads == 0
        assert coh.stats.stale_reads == 0
        assert all(v == REFRESH for (_t, _k, _v, _c, _s, v) in coh.ledger)
        assert m.coherence_invalidations > 0
        assert m.resilience_incomplete_sessions == 0


def test_write_through_never_serves_stale():
    res = _episode(policy="write-through")
    m = res.metrics
    assert m.coherence_writethroughs > 0
    assert m.coherence_stale_reads == 0 and m.coherence_invalidations == 0
    assert res.coherence.stats.stale_reads == 0


def test_bounded_staleness_contract():
    """Under ttl / serve-stale every consumed value is within its
    declared bound — replayed from the ledger, not just the max."""
    for policy, kw, bound in (("ttl", {"ttl_s": 30.0}, 30.0),
                              ("serve-stale", {"bound_s": 20.0}, 20.0),
                              ("serve-stale", {"bound_s": 5.0}, 5.0)):
        res = _episode(policy=policy, coherence_kw=kw)
        m, coh = res.metrics, res.coherence
        assert coh.policy.bound_s == bound
        served = [(s, v) for (_t, _k, _ver, _cur, s, v) in coh.ledger
                  if v == SERVE_STALE]
        assert all(s <= bound + 1e-9 for s, _v in served)
        assert m.coherence_max_staleness_s <= bound + 1e-9
        assert m.coherence_stale_reads == len(served)
        assert m.resilience_incomplete_sessions == 0


def test_no_lost_invalidations_end_state():
    """Every mutation eventually reaches every live copy: at episode end
    no cached copy (replicas included) of a mutated key lags the
    datastore version under write-invalidate or write-through."""
    for policy in ("write-invalidate", "write-through"):
        res = _episode(policy=policy, replication=True)
        coh = res.coherence
        mutated = {k for k, v in coh.versions.items() if v > 0}
        assert mutated
        for pod, cache in res.router.pods.items():
            for key, entry in cache.entries().items():
                if key in mutated:
                    assert entry.version >= coh.versions[key], (
                        policy, pod, key, entry.version, coh.versions[key])


def test_routed_invariant_holds_with_refresh_loads():
    res = _episode(policy="serve-stale", coherence_kw={"bound_s": 20.0})
    s = res.router.stats
    assert s.refresh_loads > 0                     # the new bucket is live
    assert s.routed == (s.local_hits + s.remote_loads + s.joined_in_flight
                        + s.bypass_reads)
    m = res.metrics
    assert m.coherence_refresh_loads == s.refresh_loads
    # consume accounting closes: every checkpointed read is exactly one
    # of fresh / stale-served / refreshed
    cs = res.coherence.stats
    assert cs.consumes() == cs.fresh_reads + cs.stale_reads + cs.refresh_reads


def test_stale_share_monotone_in_mutation_rate():
    """Freshness SLO: the stale-read share never decreases when the
    write rate rises (same workload, same seeds)."""
    shares = [_episode(rate=r).metrics.coherence_stale_share
              for r in (0.05, 0.2, 0.5)]
    assert shares == sorted(shares), shares
    assert shares[0] >= 0.0 and shares[-1] > shares[0]


def test_coherence_determinism():
    a = _episode(policy="serve-stale", seed=11, tasks=6)
    b = run_episode(16, 6, n_pods=4, reuse_rate=0.3, seed=11,
                    scenario="update_heavy",
                    scenario_kw={"hot_k": 4, "hot_p": 0.85},
                    mutations=MutationPlan.random_plan(HOT, 0.2, 150.0,
                                                       seed=5),
                    coherence="serve-stale")
    assert _traces(a) == _traces(b)
    assert a.coherence.ledger == b.coherence.ledger
    assert a.metrics.row() == b.metrics.row()


# ---------------------------------------------------------------------------
# Mutation x fault interplay (see also tests/test_faults.py)
# ---------------------------------------------------------------------------

def test_mutation_during_pod_failure_loses_no_invalidation():
    """A pod that is DOWN while its copies are invalidated must not
    resurrect a stale copy on restore: the failure already purged its
    cache, and every post-restore fill carries the current version."""
    plan = MutationPlan.periodic(HOT, 4.0, start_s=55.0, horizon_s=90.0)
    for policy in ("write-invalidate", "write-through"):
        res = _episode(policy=policy, replication=True,
                       fault_plan=FaultPlan.single("pod3", 60.0,
                                                   restore_at=75.0),
                       mutations=plan)
        coh = res.coherence
        assert res.metrics.resilience_failovers == 1
        assert res.metrics.resilience_incomplete_sessions == 0
        assert coh.stats.stale_reads == 0
        for pod, cache in res.router.pods.items():
            for key, entry in cache.entries().items():
                if coh.versions.get(key, 0) > 0:
                    assert entry.version >= coh.versions[key]


# ---------------------------------------------------------------------------
# GPT-driven cache_update: engine path, probe tool, golden transcript
# ---------------------------------------------------------------------------

def test_llm_coherence_in_engine():
    thr = _episode(policy="serve-stale",
                   coherence_kw={"bound_s": 20.0}).metrics
    llm = _episode(policy="serve-stale", coherence_impl="llm",
                   coherence_kw={"bound_s": 20.0}).metrics
    assert llm.coherence_agreement >= 0.90
    assert llm.coherence_tokens > 0 and thr.coherence_tokens == 0
    assert thr.coherence_agreement == 1.0
    # the engine clamp keeps the GPT path inside the bound too
    assert llm.coherence_max_staleness_s <= 20.0 + 1e-9


def test_cache_update_probe_is_side_effect_free():
    from repro.core.tools import make_coherence_tool
    res = _episode(policy="serve-stale", coherence_kw={"bound_s": 20.0})
    coh = res.coherence
    tool = make_coherence_tool(coh, None)
    before = (dict(vars(coh.stats)), list(coh.ledger))
    seen = set()
    for key in list(coh.versions) + ["never-mutated-key"]:
        out = tool.fn(key=key)
        assert out["decision"] in ("fresh", REFRESH, SERVE_STALE)
        assert out["version"] == coh.current_version(key)
        if out["decision"] == SERVE_STALE:
            assert out["staleness_s"] <= out["bound_s"] + 1e-9
        seen.add(out["decision"])
        if out["copy_version"] is None:
            assert "no cached copy" in out["reason"]
    assert "fresh" in seen
    assert before == (dict(vars(coh.stats)), list(coh.ledger))


def _build_coherence_transcript():
    """Fixed-seed LLMCoherence transcript: decisions, prompts (hashed;
    first one verbatim) and the graded agreement are deterministic, so
    any prompt/SimLLM drift diffs against the committed golden file."""
    pol = LLMCoherence(ServeStaleCoherence(bound_s=20.0),
                       SimLLM(Profile("gpt-4-turbo", "cot", True), seed=17))
    rng = random.Random(9)
    keys = ["fair1m-2017", "dota-2023", "xview1-2017", "modis-2023"]
    records = []
    example = None
    for _ in range(40):
        key = rng.choice(keys)
        staleness = round(rng.uniform(0.0, 40.0), 3)
        freq = rng.randint(0, 9)
        prompt = pol.render_prompt(key, staleness, freq)
        if example is None:
            example = prompt
        got = pol.on_stale_read(key, staleness, staleness, freq)
        records.append({
            "key": key, "staleness_s": staleness, "freq": freq,
            "prompt_sha": hashlib.sha256(prompt.encode()).hexdigest()[:16],
            "expected": pol.base.on_stale_read(key, staleness, staleness,
                                               freq),
            "decision": got,
        })
    return {
        "kind": "coherence", "policy": pol.name, "seed": 17,
        "model": "gpt-4-turbo",
        "agreement": round(pol.agreement, 4),
        "example_prompt": example,
        "decisions": records,
    }


def test_coherence_transcript_matches_golden_and_agrees():
    got = _build_coherence_transcript()
    assert got["agreement"] >= 0.90, got["agreement"]
    path = GOLDEN_DIR / "cache_update.json"
    golden = json.loads(path.read_text())
    assert got == golden, (
        f"cache_update transcript drifted from {path} — if the prompt "
        f"change is intentional, regenerate via: PYTHONPATH=src:. python "
        f"tests/golden/regen.py")


# ---------------------------------------------------------------------------
# Benchmark table + satellite capacity_arrival locks
# ---------------------------------------------------------------------------

def test_table_coherence_headline_and_locks():
    rows = tables.table_coherence(tasks_per_session=8, parallel=True)
    cells = [r.split(",") for r in rows if r.startswith("coherence,")]
    assert len(cells) == 17                 # 3 scenarios x 5 policies + 2
    by = {(c[1], c[4], float(c[5])): c for c in cells}
    # zero stale reads under write-invalidate / write-through, everywhere
    assert all(int(c[12]) == 0 for c in cells if c[4] in ("wi", "wt"))
    # declared bounds hold in every cell
    assert all(float(c[17]) <= 30.0 + 1e-9 for c in cells
               if c[4] == "ttl30")
    assert all(float(c[17]) <= 20.0 + 1e-9 for c in cells
               if c[4] in ("stale20", "llm"))
    # headline: GPT-driven serve-stale beats always-refresh WI on p95 at
    # a bounded stale share (update_heavy cell)
    llm = by[("update_heavy", "llm", 0.2)]
    assert float(llm[20]) > 1.0
    assert 0.0 < float(llm[16]) < 100.0
    assert float(llm[18]) >= 90.0
    # monotone stale share over the swept mutation rates
    pts = sorted((r, float(by[("update_heavy", "stale20", r)][16]))
                 for r in (0.05, 0.2, 0.5))
    assert [s for _r, s in pts] == sorted(s for _r, s in pts)


def _open_arrival(traffic):
    zipfg = {"scenario": "zipf", "scenario_kw": {"zipf_a": 1.1,
                                                 "zipf_global": True}}
    return run_episode(1, 25, n_pods=4, reuse_rate=0.3, seed=1,
                       prefetch=True, capacity_per_pod=8,
                       admission="tinylfu", traffic=traffic, **zipfg)


def test_capacity_arrival_cells_obey_queueing_laws():
    """ISSUE-8 satellite: the diurnal and MMPP arrival axes satisfy the
    same flow-balance / Little's-law locks as the Poisson sweep."""
    for traffic in (DiurnalTraffic(0.4, 150.0, amplitude=0.8,
                                   period_s=60.0, seed=1, lifetime_tasks=6),
                    MMPPTraffic(0.2, 1.2, 150.0, dwell_low_s=40.0,
                                dwell_high_s=15.0, seed=1,
                                lifetime_tasks=6)):
        m = _open_arrival(traffic).metrics
        assert m.traffic_spawned > 0
        assert m.traffic_spawned == m.traffic_completed
        assert m.traffic_in_system == 0
        assert m.traffic_little_residual < 1e-9
        assert m.resilience_incomplete_sessions == 0

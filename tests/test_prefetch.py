"""Event-granular scheduler + async prefetch (ISSUE 2).

Covers: exact-FCFS arrival ordering, the EventQueue determinism contract,
the router's async-completion API, prefetch-overlap accounting (a prefetched
load must never stall a later demand hit), the lazy-vs-prefetch p95 win, and
the PR-1 ``n_sessions=1`` trace replay regression.
"""
import hashlib

from repro.agent.concurrency import PodContention, run_episode
from repro.agent.geollm.simclock import EventQueue
from repro.core.distributed_cache import PodLocalCacheRouter


def _digest(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# PR-1 regression: n_sessions=1 replays the task-atomic engine's trace
# ---------------------------------------------------------------------------

# captured from the PR-1 engine (task-atomic interleaving, lazy loads) for
# run_episode(1, 8, n_pods=4, seed=0) — the solo path must not drift
PR1_SOLO_ANSWERS_DIGEST = "cd4fd32fdd08cba1"
PR1_SOLO_TOKENS = [24860, 24710, 25910, 26060, 26210, 23060, 22910, 24710]
PR1_SOLO_TIMES = [6.594662, 5.28551064, 7.052146, 5.4153324, 4.71128648,
                  5.17204584, 4.18810528, 4.27347752]


def test_solo_lazy_replays_pr1_trace_bit_identically():
    """With one session and lazy loading, the event-granular scheduler is
    observationally identical to the PR-1 task-atomic engine: answers,
    tokens AND times replay bit-identically."""
    s = run_episode(1, 8, n_pods=4, seed=0).sessions[0]
    assert _digest([t.answers for t in s.traces]) == PR1_SOLO_ANSWERS_DIGEST
    assert [t.tokens for t in s.traces] == PR1_SOLO_TOKENS
    assert [round(t.time_s, 9) for t in s.traces] == PR1_SOLO_TIMES


def test_solo_prefetch_keeps_answers_tokens_shrinks_time():
    """Prefetch only moves time: the n=1 answer/token traces stay
    bit-identical to PR-1 while every load overlaps the planning round."""
    s = run_episode(1, 8, n_pods=4, seed=0, prefetch=True).sessions[0]
    assert _digest([t.answers for t in s.traces]) == PR1_SOLO_ANSWERS_DIGEST
    assert [t.tokens for t in s.traces] == PR1_SOLO_TOKENS
    assert sum(t.time_s for t in s.traces) < sum(PR1_SOLO_TIMES)


# ---------------------------------------------------------------------------
# EventQueue determinism contract
# ---------------------------------------------------------------------------

def test_event_queue_total_order():
    q = EventQueue()
    q.push(2.0, 1, 3, "s3@2")
    q.push(2.0, 0, 9, "finish@2")     # completions before sessions at a tie
    q.push(1.0, 1, 7, "s7@1")
    q.push(2.0, 1, 1, "s1@2")         # session ties break by id
    assert [q.pop().payload for _ in range(len(q))] == \
        ["s7@1", "finish@2", "s1@2", "s3@2"]


def test_event_queue_drain_sequences_new_pushes():
    q = EventQueue()
    q.push(0.0, 1, 0, "a")
    seen = []
    for ev in q.drain():
        seen.append(ev.payload)
        if ev.payload == "a":
            q.push(5.0, 1, 0, "c")
            q.push(1.0, 1, 0, "b")
    assert seen == ["a", "b", "c"]


# ---------------------------------------------------------------------------
# Exact FCFS: pod-load arrivals are globally nondecreasing in time
# ---------------------------------------------------------------------------

def test_pod_arrivals_globally_ordered_lazy():
    res = run_episode(8, 10, n_pods=2, seed=3)
    log = res.contention.arrival_log
    assert log and log == sorted(log)


def test_pod_arrivals_globally_ordered_prefetch():
    res = run_episode(8, 10, n_pods=2, seed=3, prefetch=True)
    log = res.contention.arrival_log
    assert log and log == sorted(log)


# ---------------------------------------------------------------------------
# Router async-completion API
# ---------------------------------------------------------------------------

def test_start_finish_load_installs_at_completion():
    r = PodLocalCacheRouter(["p0", "p1"], capacity_per_pod=2)
    key = "demo-2020"
    rec = r.start_load(key, value="frame", size_bytes=7, issued_at=1.0,
                       completes_at=3.5, prefetched=True)
    assert key in r.in_flight and rec.pod == r.owner(key)
    assert key not in r.pods[rec.pod]          # not cached until completion
    assert r.stats.prefetch_issued == 1
    done = r.finish_load(key)
    assert done is rec and key not in r.in_flight
    assert key in r.pods[rec.pod]              # installed on completion


def test_demand_start_load_not_counted_as_prefetch():
    r = PodLocalCacheRouter(["p0"], capacity_per_pod=2)
    r.start_load("k-1", value=1, size_bytes=1, issued_at=0.0,
                 completes_at=1.0, prefetched=False)
    assert r.stats.prefetch_issued == 0


# ---------------------------------------------------------------------------
# Prefetch-overlap accounting
# ---------------------------------------------------------------------------

def test_prefetch_begin_never_records_stall():
    c = PodContention(["p0"])
    start, done = c.begin("p0", 0.0, 2.0)
    assert (start, done) == (0.0, 2.0)
    start2, done2 = c.begin("p0", 1.0, 2.0)    # queued behind the first
    assert (start2, done2) == (2.0, 4.0)       # FCFS window extends...
    assert c.total_stall_s == 0.0              # ...but no stall is charged
    assert c.stalled_loads == 0
    assert c.prefetch_loads == 2


def test_single_session_prefetch_never_stalls():
    """A prefetched load must never stall a later demand hit: with one
    session every planned load is prefetched and consumed, and the stall
    accounting stays at exactly zero."""
    m = run_episode(1, 10, n_pods=4, seed=0, prefetch=True).metrics
    assert m.prefetch_issued > 0
    assert m.prefetch_hits >= m.prefetch_issued   # every prefetch consumed
    assert m.total_stall_s == 0.0
    assert m.stalled_loads == 0


def test_prefetch_attribution_consistent_under_contention():
    """Session-level and pod-level accounting agree with prefetch on, and
    prefetch waits are tracked separately from stalls."""
    res = run_episode(8, 8, n_pods=4, seed=3, prefetch=True)
    per_session = sum(s.stats.stall_s for s in res.sessions)
    assert abs(per_session - res.contention.total_stall_s) < 1e-9
    assert sum(s.stats.stalled_loads for s in res.sessions) == \
        res.metrics.stalled_loads
    # physical loads: demand (remote_loads) + prefetch issuance
    assert res.metrics.total_loads == \
        res.router.stats.remote_loads + res.router.stats.prefetch_issued
    # logical accesses: hits + demand loads + in-flight joins
    s = res.router.stats
    assert s.routed == s.local_hits + s.remote_loads + s.joined_in_flight
    # overlap credit is bounded by the total prefetched dwell
    assert 0.0 <= res.metrics.overlap_credit_s
    assert res.metrics.prefetch_wait_s >= 0.0


def test_prefetch_answers_independent_of_mode():
    """Prefetch shifts time, never answers: every session's answer trace is
    identical between lazy and prefetch runs of the same episode."""
    lazy = run_episode(4, 6, n_pods=4, seed=5)
    pf = run_episode(4, 6, n_pods=4, seed=5, prefetch=True)
    for sl, sp in zip(lazy.sessions, pf.sessions):
        assert [t.answers for t in sl.traces] == [t.answers for t in sp.traces]
        assert [t.success for t in sl.traces] == [t.success for t in sp.traces]


def test_prefetch_deterministic():
    a = run_episode(6, 6, n_pods=4, seed=9, prefetch=True).metrics.row()
    b = run_episode(6, 6, n_pods=4, seed=9, prefetch=True).metrics.row()
    assert a == b


# ---------------------------------------------------------------------------
# The headline property: prefetch cuts tail latency under concurrency
# ---------------------------------------------------------------------------

def test_prefetch_reduces_p95_at_8_sessions():
    """Acceptance: at >=8 sessions with overlapping keys (reuse 0.8),
    prefetch strictly reduces p95 task latency vs lazy loading."""
    lazy = run_episode(8, 25, n_pods=8, seed=0).metrics
    pf = run_episode(8, 25, n_pods=8, seed=0, prefetch=True).metrics
    assert pf.p95_task_latency_s < lazy.p95_task_latency_s
    assert pf.p50_task_latency_s < lazy.p50_task_latency_s
    assert pf.overlap_credit_s > 0.0


def test_prefetch_joins_dedupe_db_loads():
    """Sessions needing a key already in flight join the existing load
    instead of re-issuing DB service."""
    res = run_episode(16, 10, n_pods=4, seed=0, prefetch=True)
    assert res.metrics.joined_loads > 0
    # every join saved one physical DB load
    s = res.router.stats
    assert res.contention.total_loads == s.remote_loads + s.prefetch_issued

"""Tests for the §Perf beyond-paper optimizations (EXPERIMENTS.md)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Init, decode_step, init_model, prefill_step, unbox
from repro.models.model import forward


RNG = np.random.default_rng(7)


def _params(cfg):
    return unbox(init_model(Init(jax.random.PRNGKey(0),
                                 dtype=cfg.jnp_dtype), cfg))[0]


@pytest.mark.slow
def test_int8_kv_cache_close_to_fp():
    cfg0 = dataclasses.replace(get_config("qwen1.5-32b").reduced(),
                               dtype="float32")
    cfg1 = dataclasses.replace(cfg0, kv_quant=True)
    params = _params(cfg0)
    toks = jnp.asarray(RNG.integers(0, cfg0.vocab_size, (2, 12)), jnp.int32)
    c0, l0 = prefill_step(cfg0, params, {"tokens": toks}, max_len=16)
    c1, l1 = prefill_step(cfg1, params, {"tokens": toks}, max_len=16)
    assert c1["k"].dtype == jnp.int8 and "k_scale" in c1
    t = jnp.argmax(l0[:, -1], -1)[:, None].astype(jnp.int32)
    d0, _ = decode_step(cfg0, params, t, c0)
    d1, _ = decode_step(cfg1, params, t, c1)
    assert float(jnp.max(jnp.abs(d0 - d1))) < 0.15


@pytest.mark.parametrize("arch", ["hymba-1.5b", "mixtral-8x22b",
                                  "llama4-maverick-400b-a17b"])
@pytest.mark.slow
def test_windowed_kv_slicing_matches_full_attention(arch):
    """The §Perf KV-slicing fast path must be bit-for-bit equivalent to
    full-row chunked attention (same mask, fewer scored keys)."""
    import repro.models.attention as A
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = _params(cfg)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 64)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.frontend == "vision_patches":
        batch["patches"] = jnp.asarray(
            RNG.normal(size=(2, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    orig = A._pick_chunk
    try:
        A._pick_chunk = lambda s, target=16: 16 if s % 16 == 0 else s
        h_sliced, _, _ = forward(cfg, params, batch, is_train=False)
        A._pick_chunk = lambda s, target=16: s       # one chunk: full row
        h_full, _, _ = forward(cfg, params, batch, is_train=False)
    finally:
        A._pick_chunk = orig
    np.testing.assert_allclose(np.asarray(h_sliced), np.asarray(h_full),
                               atol=2e-5, rtol=2e-5)


def test_moe_decode_dropless():
    """Small decode groups must never drop tokens (moe_capacity)."""
    from repro.models.mlp_moe import moe_capacity
    cfg = get_config("mixtral-8x22b")
    assert moe_capacity(cfg, 2) == 2 * cfg.moe.top_k
    assert moe_capacity(cfg, 8) == 8 * cfg.moe.top_k
    # large groups stay capacity-bounded
    assert moe_capacity(cfg, 1024) < 1024 * cfg.moe.top_k


def test_grad_cast_keeps_cotangent_dtype():
    from repro.models.common import grad_cast

    def f(x):
        y = grad_cast(x.astype(jnp.bfloat16), jnp.bfloat16)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    g = jax.grad(f)(jnp.ones((4,), jnp.float32))
    assert np.isfinite(np.asarray(g)).all()


def test_serve_rules_divisibility():
    from repro.distributed.sharding import (
        expert_parallel_rules,
        logical_to_spec,
        serve_rules,
        single_pod_rules,
    )

    class M:
        shape = {"data": 16, "model": 16}

    r = serve_rules(single_pod_rules())
    # dense weights: no FSDP axis at serve time
    assert logical_to_spec(("embed", "mlp"), (5120, 8192), M(), r)[0] is None
    # llama4 experts shard over data; mixtral E=8 falls back safely
    ep = expert_parallel_rules(single_pod_rules())
    spec128 = logical_to_spec(("experts", "embed", "mlp"),
                              (128, 5120, 8192), M(), ep)
    assert spec128[0] == "data"
    spec8 = logical_to_spec(("experts", "embed", "mlp"),
                            (8, 6144, 16384), M(), ep)
    assert spec8[0] is None

import jax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    logical_to_spec,
    multi_pod_rules,
    single_pod_rules,
)


class FakeMesh:
    """Duck-typed mesh exposing .shape mapping (enough for spec derivation)."""
    def __init__(self, shape):
        self.shape = shape


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_weight_spec():
    spec = logical_to_spec(("embed", "mlp"), (4096, 16384), MESH1,
                           single_pod_rules())
    assert spec == P("data", "model")


def test_divisibility_fallback_vocab():
    # 49155 % 16 != 0 -> vocab axis falls back to replication
    spec = logical_to_spec(("vocab", "embed"), (49155, 2048), MESH1,
                           single_pod_rules())
    assert spec == P(None, "data")
    # padded vocab shards fine
    spec2 = logical_to_spec(("vocab", "embed"), (49408, 2048), MESH1,
                            single_pod_rules())
    assert spec2 == P("model", "data")


def test_batch_one_replicates():
    spec = logical_to_spec(("batch", "seq", "act_embed"), (1, 524288, 4096),
                           MESH1, single_pod_rules())
    assert spec == P(None, None, None)


def test_multi_pod_batch_axis():
    spec = logical_to_spec(("batch", "seq"), (256, 4096), MESH2,
                           multi_pod_rules())
    assert spec == P(("pod", "data"), None)


def test_multi_axis_prefix_fallback():
    # batch=16 divisible by data(16) but not pod*data(32): falls back to
    # the longest divisible prefix ("pod",)? 16 % 2 == 0 -> ("pod",)
    spec = logical_to_spec(("batch",), (16,), MESH2, multi_pod_rules())
    assert spec in (P("pod"), P(("pod",)))


def test_mesh_axis_not_reused_in_one_spec():
    rules = single_pod_rules()
    # both dims want "model": second one must drop
    spec = logical_to_spec(("mlp", "kv"), (16384, 1024), MESH1, rules)
    flat = []
    for e in spec:
        if e is None:
            continue
        flat.extend([e] if isinstance(e, str) else list(e))
    assert len(flat) == len(set(flat))


def test_empty_name_means_replicated():
    spec = logical_to_spec(("", "embed"), (7, 2048), MESH1,
                           single_pod_rules())
    assert spec == P(None, "data")


def test_production_mesh_axes_present():
    rules = multi_pod_rules()
    assert rules["embed"] == ("pod", "data")
    assert rules["batch"] == ("pod", "data")

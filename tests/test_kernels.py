"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def arr(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,Hq,Hkv,S,d", [
    (2, 4, 2, 256, 64), (1, 8, 8, 128, 128), (2, 6, 2, 128, 32),
    (1, 4, 1, 512, 64),
])
@pytest.mark.parametrize("mask", ["causal", "window", "chunk", "full"])
def test_flash_attention_sweep(B, Hq, Hkv, S, d, mask):
    kw = {"causal": dict(causal=True),
          "window": dict(causal=True, window=64),
          "chunk": dict(causal=True, chunk=128),
          "full": dict(causal=False)}[mask]
    q, k, v = arr(B, Hq, S, d), arr(B, Hkv, S, d), arr(B, Hkv, S, d)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, **kw)
    gold = ref.ref_flash_attention(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold),
                               **tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q, k, v = (arr(1, 4, 128, 64, dtype=dtype) for _ in range(3))
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    gold = ref.ref_flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,Hq,Hkv,C,d", [
    (2, 4, 2, 256, 64), (3, 8, 8, 128, 32), (1, 16, 2, 512, 128),
])
@pytest.mark.parametrize("mask", ["none", "window", "chunk"])
def test_decode_attention_sweep(B, Hq, Hkv, C, d, mask):
    kw = {"none": {}, "window": dict(window=64),
          "chunk": dict(chunk=128)}[mask]
    q, k, v = arr(B, Hq, d), arr(B, Hkv, C, d), arr(B, Hkv, C, d)
    pos = jnp.asarray(RNG.integers(1, 3 * C, B), jnp.int32)
    out = ops.decode_attention(q, k, v, pos, block_k=64, **kw)
    gold = ref.ref_decode_attention(q, k, v, pos, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold),
                               **tol(jnp.float32))


def test_decode_attention_short_history():
    """pos < C: unwritten ring slots must be masked out."""
    B, Hq, Hkv, C, d = 2, 4, 2, 128, 64
    q, k, v = arr(B, Hq, d), arr(B, Hkv, C, d), arr(B, Hkv, C, d)
    pos = jnp.asarray([3, 17], jnp.int32)
    out = ops.decode_attention(q, k, v, pos, block_k=64)
    gold = ref.ref_decode_attention(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold),
                               **tol(jnp.float32))


@pytest.mark.parametrize("B,H,S,hd", [(2, 3, 128, 64), (1, 2, 64, 32)])
@pytest.mark.parametrize("chunk", [16, 64])
def test_wkv_sweep(B, H, S, hd, chunk):
    r, k, v = arr(B, H, S, hd), arr(B, H, S, hd), arr(B, H, S, hd)
    w = jnp.asarray(RNG.uniform(0.8, 0.999, (B, H, S, hd)), jnp.float32)
    u = arr(H, hd)
    y, s = ops.wkv(r, k, v, w, u, chunk=chunk)
    yg, sg = ref.ref_wkv(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yg),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sg),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(8, 256), (2, 5, 128), (3, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = arr(*shape, dtype=dtype)
    g = arr(shape[-1], dtype=dtype)
    out = ops.rmsnorm(x, g)
    gold = ref.ref_rmsnorm(x, g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **tol(dtype))
